"""Parameter-grid sweeps through the service: one request, many points.

A ``SweepRequest`` names a single-function template and a grid; the
engine canonicalizes the grid into fixed-size slices of swept families
(``canonical.sweep_slices``), so the whole scan runs on the fused
swept-kernel path and cache streams key per (family, grid-slice).  The
invariants asserted here:

* **end to end** — a sweep returns per-point estimates in row-major
  grid order, bit-identical to submitting each grid point as its own
  request on a fresh engine (same global function ids);
* **sub-grid dedupe** — a second sweep extending the slowest axis pays
  launches only for its NEW canonical slices and reproduces the shared
  prefix byte for byte; a verbatim resubmit is a pure cache hit and a
  budget top-up pays only the delta rounds (STR semantics carry over);
* **streaming** — ``sweep_partial`` snapshots an in-flight sweep
  without blocking: undone points hold NaN/inf under a ``points_done``
  mask, finished rounds surface before the ticket completes;
* **durability** — sweep streams journal and restart like any other
  stream: a post-kill engine serves the same sweep with zero launches;
* **eager capability gating** — a sweep over a parameter the kernel
  form cannot substitute fails at submit time with the registry's
  capability diagnostic, not at first wave.
"""

import numpy as np
import pytest

from repro.core import genz, harmonic_family
from repro.kernels import template
from repro.service import IntegrationClient, SweepRequest
from repro.service.api import SweepResult

R = 4096

A4 = np.linspace(0.5, 2.0, 4).astype(np.float32)
B2 = np.asarray([-0.5, 1.5], np.float32)


def _drain(engine):
    while engine.step():
        pass


@pytest.mark.parametrize("sampler", ["mc", "sobol"])
def test_sweep_end_to_end_bit_identical_to_per_point(make_engine, sampler):
    res = IntegrationClient(make_engine()).sweep(
        harmonic_family(1, 2), {"a": A4, "b": B2}, n_samples=R,
        sampler=sampler)
    assert isinstance(res, SweepResult) and res.complete
    assert res.grid_shape == (4, 2) and res.axis_names == ("a", "b")
    assert res.n_points == 8 == res.means.shape[0]
    assert res.points_done.all() and np.isfinite(res.means).all()

    # fresh engine, same seed: sequential per-point requests draw the
    # same global function ids 0..7 -> byte-for-byte agreement
    per = IntegrationClient(make_engine())
    flat = []
    for ai in A4:                      # sorted axes, last ("b") fastest
        for bi in B2:
            one = per.integrate(
                [harmonic_family(1, 2, a=np.asarray([ai]),
                                 b=np.asarray([bi]))],
                n_samples=R, sampler=sampler)
            flat.append(one.means[0])
    np.testing.assert_array_equal(
        np.asarray(flat, res.means.dtype), res.means)


def test_overlapping_sweeps_dedupe_at_subgrid_level(make_engine):
    engine = make_engine(sweep_slice_points=4)
    client = IntegrationClient(engine)
    template.reset_launch_count()
    first = client.sweep(harmonic_family(1, 2), {"a": A4, "b": B2},
                         n_samples=R)
    cold_launches = template.launch_count()
    assert cold_launches >= 1

    # extend the slowest axis ("a"): the first 8 points re-enumerate
    # sweep A's two canonical slices exactly
    a8 = np.concatenate([A4, A4 + 2.0])
    template.reset_launch_count()
    second = client.sweep(harmonic_family(1, 2), {"a": a8, "b": B2},
                          n_samples=R)
    assert second.n_points == 16
    # same bucket, same budget: the two NEW slices fit the same wave
    # shape the cold sweep needed, never more
    assert 1 <= template.launch_count() <= cold_launches
    np.testing.assert_array_equal(second.means[:8], first.means)

    # verbatim resubmit: every slice is already at precision
    template.reset_launch_count()
    warm = client.sweep(harmonic_family(1, 2), {"a": A4, "b": B2},
                        n_samples=R)
    assert template.launch_count() == 0 and warm.served_from_cache
    np.testing.assert_array_equal(warm.means, first.means)

    # budget top-up: existing sweep streams extend, means change
    topped = client.sweep(harmonic_family(1, 2), {"a": A4, "b": B2},
                          n_samples=2 * R)
    assert not topped.served_from_cache
    assert all(n >= 2 * R for n in topped.n_per_family)


def test_sweep_partial_streams_before_completion(make_engine):
    engine = make_engine(max_rounds_per_wave=1)
    ticket = engine.submit(SweepRequest.make(
        harmonic_family(1, 2), {"a": A4, "b": B2}, n_samples=2 * R))

    # nothing deposited yet: masked-out NaN means, inf stderrs
    snap = engine.sweep_partial(ticket)
    assert not snap.complete and not snap.points_done.any()
    assert np.isnan(snap.means).all() and np.isinf(snap.stderrs).all()

    # one single-round wave: every slice has a first estimate but the
    # 2-round budget is not met -> streamed, still incomplete
    assert engine.step()
    mid = engine.sweep_partial(ticket)
    assert not mid.complete and mid.points_done.all()
    assert np.isfinite(mid.means).all()
    assert engine.poll(ticket) is None

    _drain(engine)
    done = engine.sweep_partial(ticket)
    assert done.complete and done.points_done.all()
    np.testing.assert_array_equal(done.means, engine.poll(ticket).means)


def test_sweep_partial_since_final_partial_slice(make_engine):
    """65 points under a 64-point slice quantum: [64, 1] slices.

    Regression for the final-slice off-by-one: the ``since`` mask must
    align point-exactly with the short last slice — a stale mask offset
    either misreads the last slice as seen (dropping its only point) or
    reads past the mask.  Asserted here: the 65th point streams like any
    other, an all-seen poll placeholders everything, and a mask covering
    only the full slice re-finalizes just the final point.
    """
    engine = make_engine(max_rounds_per_wave=1)
    a65 = np.linspace(0.5, 2.0, 65).astype(np.float32)
    ticket = engine.submit(SweepRequest.make(
        harmonic_family(1, 2), {"a": a65}, n_samples=2 * R))
    assert engine.step()

    first = engine.sweep_partial(ticket)
    assert first.n_points == 65 and first.points_done.all()
    assert np.isfinite(first.means).all() and not first.complete

    # all 65 points seen: both slices done, pure placeholders
    seen = engine.sweep_partial(ticket, since=first.points_done)
    assert seen.points_done.all()
    assert np.isnan(seen.means).all() and np.isinf(seen.stderrs).all()

    # only the full 64-point slice seen: its points placeholder out,
    # the single-point final slice finalizes for real
    mask = first.points_done.copy()
    mask[-1] = False
    tail = engine.sweep_partial(ticket, since=mask)
    assert tail.points_done.all()
    assert np.isnan(tail.means[:64]).all()
    np.testing.assert_array_equal(tail.means[64:], first.means[64:])
    np.testing.assert_array_equal(tail.stderrs[64:], first.stderrs[64:])

    # a partially-seen full slice is NOT skipped: every unseen point of
    # it re-finalizes (slice granularity, point-exact mask)
    mask2 = np.zeros(65, bool)
    mask2[:32] = True
    mid = engine.sweep_partial(ticket, since=mask2)
    np.testing.assert_array_equal(mid.means, first.means)

    with pytest.raises(ValueError, match="since mask"):
        engine.sweep_partial(ticket, since=np.ones(64, bool))

    _drain(engine)
    done = engine.sweep_partial(ticket, since=np.zeros(65, bool))
    assert done.complete
    np.testing.assert_array_equal(done.means, engine.poll(ticket).means)


def test_sweep_partial_rejects_non_sweep_tickets(make_engine):
    from repro.service import IntegrationRequest
    engine = make_engine()
    plain = engine.submit(IntegrationRequest.make(
        [harmonic_family(2, 2)], n_samples=R))
    with pytest.raises(TypeError, match="not a sweep"):
        engine.sweep_partial(plain)
    _drain(engine)
    with pytest.raises(TypeError, match="not a sweep"):
        engine.sweep_partial(plain)
    with pytest.raises(KeyError, match="unknown ticket"):
        engine.sweep_partial(10_000)


def test_sweep_streams_survive_a_kill(make_engine, tmp_path):
    grid = {"a": A4, "b": B2}
    first = IntegrationClient(make_engine(state_dir=str(tmp_path))).sweep(
        harmonic_family(1, 2), grid, n_samples=R)
    # no close(): the journal is all that survives the "SIGKILL"
    e2 = make_engine(state_dir=str(tmp_path))
    template.reset_launch_count()
    again = IntegrationClient(e2).sweep(harmonic_family(1, 2), grid,
                                        n_samples=R)
    assert template.launch_count() == 0 and again.served_from_cache
    np.testing.assert_array_equal(first.means, again.means)
    assert again.grid_shape == (4, 2) and again.complete


def test_unsweepable_parameter_fails_at_submit(make_engine):
    """genz_osc's "u" reaches the packed row only as u[:, :1]; the form
    excludes it from sweep_cols, and the engine surfaces the registry
    diagnostic before any wave runs."""
    tmpl, _ = genz.oscillatory(1, 2)
    u = np.linspace(0.1, 0.9, 4)[:, None] * np.ones(2, np.float32)
    req = SweepRequest.make(tmpl, {"u": u}, n_samples=R)
    with pytest.raises(ValueError, match="not sweepable"):
        make_engine().submit(req)


def test_sweep_request_validation():
    tmpl = harmonic_family(1, 2)
    with pytest.raises(ValueError, match="single function"):
        SweepRequest.make(harmonic_family(2, 2), {"a": A4}, n_samples=R)
    with pytest.raises(ValueError, match="at least one axis"):
        SweepRequest.make(tmpl, {}, n_samples=R)
    with pytest.raises(ValueError, match="not in"):
        SweepRequest.make(tmpl, {"nope": A4}, n_samples=R)
    with pytest.raises(ValueError, match="n_samples or target_stderr"):
        SweepRequest.make(tmpl, {"a": A4})
    with pytest.raises(ValueError, match="unknown sampler"):
        SweepRequest.make(tmpl, {"a": A4}, n_samples=R, sampler="qmc")
    with pytest.raises(ValueError, match="must be positive"):
        SweepRequest.make(tmpl, {"a": A4}, n_samples=-1)
