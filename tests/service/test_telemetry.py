"""Telemetry through the engine: traces cover the pipeline, metrics agree
with engine observables, failure paths emit attributable events, and
every completed request exposes a stderr-vs-rounds trajectory.

These are the service-level counterparts of ``tests/obs``: the obs tests
exercise the primitives in isolation; here the assertion is that the
*wiring* through plan/launch/deposit is complete and honest.
"""

import pytest

from repro.core import gaussian_family, harmonic_family
from repro.distributed.fault_tolerance import StepWatchdog
from repro.kernels import template
from repro.obs import Observability
from repro.obs.trace import STAGES
from repro.service import IntegrationClient

R = 4096


@pytest.fixture
def events():
    return []


@pytest.fixture
def obs(events):
    """A live Observability bundle whose trace feeds a plain list."""
    o = Observability.enabled(sinks=(events.append,))
    yield o
    o.close()


def _instants(events, name):
    return [e for e in events if e.get("ph") == "i" and e["name"] == name]


class TestTraceCoverage:
    def test_sync_wave_covers_all_six_stages(self, make_engine, obs, events,
                                             tmp_path):
        engine = make_engine(state_dir=str(tmp_path), obs=obs)
        IntegrationClient(engine).integrate(
            [harmonic_family(3, 2), gaussian_family(2, 2)], n_samples=2 * R)
        spans = {e["name"] for e in events if e.get("ph") == "X"}
        assert spans.issuperset(STAGES)

    def test_wal_commit_absent_without_durable_store(self, make_engine, obs,
                                                     events):
        engine = make_engine(obs=obs)
        IntegrationClient(engine).integrate([harmonic_family(3, 2)],
                                            n_samples=R)
        spans = {e["name"] for e in events if e.get("ph") == "X"}
        assert "wal_commit" not in spans
        assert spans.issuperset(set(STAGES) - {"wal_commit"})


class TestMetricAgreement:
    def test_counters_match_engine_observables(self, make_engine, obs):
        template.reset_launch_count()
        engine = make_engine(obs=obs)
        client = IntegrationClient(engine)
        client.integrate([harmonic_family(3, 2)], n_samples=3 * R)
        client.integrate([gaussian_family(2, 2), harmonic_family(2, 2)],
                         n_samples=2 * R)
        m = obs.m
        assert m["launches"].value() == template.launch_count()
        assert m["fallback_rounds"].value() == engine.batcher.fallback_rounds
        assert m["waves"].value() == engine.stats.waves
        assert m["served"].value() == engine.stats.served == 2
        assert m["submitted"].value() == engine.stats.submitted == 2

    def test_warm_replay_counts_cache_hit_and_zero_launch(self, make_engine,
                                                          obs):
        engine = make_engine(obs=obs)
        client = IntegrationClient(engine)
        fam = [harmonic_family(3, 2)]
        client.integrate(fam, n_samples=2 * R)
        waves_before = engine.stats.waves
        client.integrate(fam, n_samples=2 * R)       # identical → cache
        assert engine.stats.waves == waves_before
        assert obs.m["cache_requests"].value(outcome="hit") >= 1
        assert obs.m["warm_zero_launch"].value() == 1
        assert obs.m["served"].value() == 2

    def test_gauges_drain_to_zero_at_quiescence(self, make_engine, obs):
        engine = make_engine(obs=obs)
        IntegrationClient(engine).integrate([harmonic_family(3, 2)],
                                            n_samples=2 * R)
        assert obs.m["pending"].value() == 0
        assert obs.m["inflight"].value() == 0


class TestConvergenceAccounting:
    def test_every_result_stream_has_a_trajectory(self, make_engine, obs):
        engine = make_engine(obs=obs)
        res = IntegrationClient(engine).integrate(
            [harmonic_family(3, 2), gaussian_family(2, 2)], n_samples=4 * R)
        assert len(res.stream_ids) == 2
        for sid in res.stream_ids:
            traj = engine.stderr_trajectory(sid)
            assert traj, sid
            rounds = [p.rounds_done for p in traj]
            assert rounds == sorted(rounds)
            assert traj[-1].rounds_done == 4        # full budget deposited
            assert traj[-1].stderr_max > 0

    def test_stderr_decreases_with_rounds(self, make_engine, obs):
        engine = make_engine(obs=obs)
        res = IntegrationClient(engine).integrate([harmonic_family(3, 2)],
                                                  n_samples=8 * R)
        (sid,) = res.stream_ids
        traj = engine.stderr_trajectory(sid)
        assert len(traj) >= 2
        assert traj[-1].stderr_max < traj[0].stderr_max

    def test_disabled_obs_keeps_api_shape(self, make_engine):
        engine = make_engine()                       # Observability.disabled()
        res = IntegrationClient(engine).integrate([harmonic_family(3, 2)],
                                                  n_samples=R)
        assert len(res.stream_ids) == 1
        assert engine.stderr_trajectory(res.stream_ids[0]) == []


class TestFailurePathEvents:
    def test_torn_deposit_emits_restart_event_with_identity(
            self, make_engine, obs, events, tmp_path):
        engine = make_engine(state_dir=str(tmp_path), max_rounds_per_wave=8,
                             obs=obs)
        store = engine.store
        orig = store.append_deposits
        fails = {"left": 1}

        def flaky(payloads):
            payloads = list(payloads)
            if fails["left"]:
                fails["left"] -= 1
                orig(payloads[:1])
                raise OSError("injected torn group commit")
            return orig(payloads)

        store.append_deposits = flaky
        res = IntegrationClient(engine).integrate([harmonic_family(4, 3)],
                                                  n_samples=3 * R)
        assert engine.stats.restarts == 1
        (ev,) = _instants(events, "wave_restart")
        assert ev["args"]["error"] == "OSError"
        assert ev["args"]["attempt"] == 0
        # the event names the streams the replayed wave was computing
        assert res.stream_ids[0][:16] in ev["args"]["streams"]
        assert obs.m["restarts"].value() == 1

    def test_pipelined_deposit_retry_event(self, make_engine, obs, events,
                                           tmp_path):
        engine = make_engine(state_dir=str(tmp_path), max_rounds_per_wave=8,
                             obs=obs)
        store = engine.store
        orig = store.append_deposits
        fails = {"left": 1}

        def flaky(payloads):
            if fails["left"]:
                fails["left"] -= 1
                raise OSError("injected commit failure")
            return orig(payloads)

        store.append_deposits = flaky
        engine.start()
        res = IntegrationClient(engine).integrate([harmonic_family(4, 3)],
                                                  n_samples=3 * R)
        engine.stop()
        retries = _instants(events, "deposit_retry")
        assert retries, [e["name"] for e in events if e.get("ph") == "i"]
        assert retries[0]["args"]["error"] == "OSError"
        assert res.stream_ids[0][:16] in retries[0]["args"]["streams"]
        assert obs.m["restarts"].value() >= 1

    def test_straggler_event_carries_wave_and_stream(self, make_engine, obs,
                                                     events):
        # a watchdog pre-seeded with an instant history makes the very
        # first (real, nonzero-duration) wave a straggler
        dog = StepWatchdog(threshold=0.0, warmup=1)
        dog.durations.append(0.0)
        engine = make_engine(obs=obs, watchdog=dog)
        res = IntegrationClient(engine).integrate([harmonic_family(3, 2)],
                                                  n_samples=R)
        assert dog.straggler_count >= 1
        evs = _instants(events, "straggler")
        assert len(evs) == dog.straggler_count
        assert evs[0]["args"]["duration"] > 0
        assert res.stream_ids[0][:16] in evs[0]["args"]["streams"]
        assert obs.m["stragglers"].value() == dog.straggler_count
