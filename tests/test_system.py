"""End-to-end behaviour: train -> crash -> resume == uninterrupted;
serve generates; integrate reproduces the paper's numbers at small scale."""

import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.launch.train import TrainHParams, train_loop


def _hp(steps):
    import dataclasses
    return dataclasses.replace(TrainHParams(), total_steps=steps,
                               warmup_steps=2, grad_accum=2, lr=1e-3)


def test_crash_resume_trajectory_identical(tmp_path):
    cfg = reduced(get_config("stablelm_3b"))
    # uninterrupted oracle
    _, losses_ref, _ = train_loop(cfg, _hp(10), batch=4, seq=32, steps=10,
                                  ckpt_dir=None, log_every=100)
    # crash at step 7, then resume from the step-5 checkpoint
    with pytest.raises(RuntimeError, match="injected"):
        train_loop(cfg, _hp(10), batch=4, seq=32, steps=10,
                   ckpt_dir=str(tmp_path), ckpt_every=5, log_every=100,
                   fail_at_step=7)
    _, losses_resumed, _ = train_loop(cfg, _hp(10), batch=4, seq=32,
                                      steps=10, ckpt_dir=str(tmp_path),
                                      ckpt_every=100, log_every=100)
    # resumed run re-plays steps 5..9; trajectories must coincide
    np.testing.assert_allclose(losses_resumed, losses_ref[5:], rtol=1e-5)


def test_loss_decreases_over_training():
    """Repeated steps on one fixed batch must be memorised (the streaming
    pipeline feeds fresh random tokens whose optimal loss is ln V, so the
    loss signal there is flat by construction)."""
    import jax
    from repro.launch.specs import concrete_batch
    from repro.launch.train import make_train_state, make_train_step
    from repro.models.model import Model
    cfg = reduced(get_config("minitron_4b"))
    model = Model(cfg)
    hp = _hp(30)
    state = make_train_state(model, hp, jax.random.key(0))
    step = jax.jit(make_train_step(model, hp))
    batch = concrete_batch(cfg, 4, 32, train=True)
    losses = []
    for _ in range(15):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])
    assert all(np.isfinite(l) for l in losses)


def test_server_generates_consistently():
    from repro.launch.serve import Server
    from repro.launch.specs import concrete_batch
    cfg = reduced(get_config("mamba2_130m"))
    server = Server(cfg, seed=0)
    batch = concrete_batch(cfg, 2, 8, train=False)
    toks1 = server.generate(batch, 6, seq_cap=16)
    toks2 = server.generate(batch, 6, seq_cap=16)
    assert toks1.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(toks1), np.asarray(toks2))
    assert np.asarray(toks1).min() >= 0
    assert np.asarray(toks1).max() < cfg.vocab_padded


def test_paper_validation_small():
    """Scaled-down Fig. 1: the trial band must bracket the analytic curve."""
    from repro.core import (ZMCMultiFunctions, harmonic_analytic,
                            harmonic_family)
    z = ZMCMultiFunctions([harmonic_family(30, 4)], n_samples=60_000, seed=0)
    r = z.evaluate(num_trials=5)
    exact = harmonic_analytic(30, 4)
    within = (np.abs(r.trial_mean - exact)
              <= 3 * np.maximum(r.trial_std, 1e-12))
    assert within.mean() >= 0.9
