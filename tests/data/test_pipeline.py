"""Deterministic sharded data pipeline."""

import numpy as np

from repro.configs import get_config, reduced
from repro.data.pipeline import TokenStream


def _cfg(name="stablelm_3b"):
    return reduced(get_config(name))


def test_deterministic_across_instances():
    a = TokenStream(_cfg(), 8, 32, seed=3).next_batch()
    b = TokenStream(_cfg(), 8, 32, seed=3).next_batch()
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))


def test_steps_differ():
    s = TokenStream(_cfg(), 4, 16, seed=0)
    b0 = s.next_batch()
    b1 = s.next_batch()
    assert not np.array_equal(np.asarray(b0["tokens"]),
                              np.asarray(b1["tokens"]))


def test_snapshot_restore_resumes_stream():
    s = TokenStream(_cfg(), 4, 16, seed=1)
    s.next_batch()
    snap = s.snapshot()
    b_next = s.next_batch()
    s2 = TokenStream(_cfg(), 4, 16, seed=1)
    s2.restore(snap)
    b_resume = s2.next_batch()
    np.testing.assert_array_equal(np.asarray(b_next["tokens"]),
                                  np.asarray(b_resume["tokens"]))


def test_row_sharding_consistent():
    """A host holding rows [2,3] sees exactly those rows of the global batch."""
    s_full = TokenStream(_cfg(), 8, 16, seed=2)
    s_part = TokenStream(_cfg(), 8, 16, seed=2)
    full = s_full.next_batch()
    part = s_part.next_batch(rows=np.array([2, 3]))
    np.testing.assert_array_equal(np.asarray(full["tokens"][2:4]),
                                  np.asarray(part["tokens"]))


def test_tokens_in_vocab():
    cfg = _cfg()
    b = TokenStream(cfg, 4, 64, seed=5).next_batch()
    t = np.asarray(b["tokens"])
    assert t.min() >= 0 and t.max() < cfg.vocab_size


def test_modalities():
    enc = TokenStream(_cfg("hubert_xlarge"), 2, 16, seed=0).next_batch()
    assert set(enc) == {"frames", "labels"}
    assert enc["frames"].shape == (2, 16, 32)
    vlm = TokenStream(_cfg("qwen2_vl_7b"), 2, 16, seed=0).next_batch()
    assert {"tokens", "labels", "vision_embeds", "positions"} <= set(vlm)
    assert vlm["positions"].shape == (3, 2, 16)
