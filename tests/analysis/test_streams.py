"""Layer-3 determinism analyzer: seeded-bad state dirs fire STR rules at
the right journal record, real engine state audits clean, and the live
debug hooks share the same predicates without false positives."""

import numpy as np
import pytest

from repro.analysis import streams
from repro.service.store import DurableStore, EntryState

RS = 64   # round quantum used by all fixture state dirs


@pytest.fixture
def state_dir(tmp_path):
    return str(tmp_path / "state")


def _store(state_dir):
    return DurableStore(state_dir, fsync=False)


def _dep(store, chash, round_index, n_fn, n=RS):
    return store.deposit_record(chash, round_index,
                                np.ones(n_fn, np.float32),
                                np.ones(n_fn, np.float32), n)


def _rules(report):
    return [v.rule for v in report.violations]


def _edges(n_bins=4):
    """A well-formed (1, 2, n_bins + 1) importance grid."""
    e = np.linspace(0.0, 1.0, n_bins + 1, dtype=np.float32)
    return np.broadcast_to(e, (1, 2, n_bins + 1)).copy()


class TestAuditSeededViolations:
    def test_overlapping_counter_ranges_fire_str001(self, state_dir):
        store = _store(state_dir)
        store.append_alloc("aaa", fn_offset=0, n_fn=8, round_samples=RS)
        store.append_alloc("bbb", fn_offset=4, n_fn=8, round_samples=RS)
        store.close()
        report = streams.audit_state_dir(state_dir)
        assert _rules(report) == ["STR001"]
        v = report.violations[0]
        assert v.path.endswith("journal.bin") and v.line == 2

    def test_deposit_gap_fires_str002(self, state_dir):
        store = _store(state_dir)
        store.append_alloc("aaa", fn_offset=0, n_fn=8, round_samples=RS)
        store.append_deposits([_dep(store, "aaa", 1, 8)])   # skips round 0
        store.close()
        report = streams.audit_state_dir(state_dir)
        assert _rules(report) == ["STR002"]
        assert report.violations[0].line == 2

    def test_shape_mismatch_fires_str003(self, state_dir):
        store = _store(state_dir)
        store.append_alloc("aaa", fn_offset=0, n_fn=8, round_samples=RS)
        store.append_deposits([_dep(store, "aaa", 0, n_fn=3)])
        store.close()
        report = streams.audit_state_dir(state_dir)
        assert _rules(report) == ["STR003"]

    def test_quantum_mismatch_fires_str003(self, state_dir):
        store = _store(state_dir)
        store.append_alloc("aaa", fn_offset=0, n_fn=8, round_samples=RS)
        store.append_deposits([_dep(store, "aaa", 0, 8, n=RS + 1)])
        store.close()
        report = streams.audit_state_dir(state_dir)
        assert _rules(report) == ["STR003"]

    def test_allocator_regression_fires_str004(self, state_dir):
        store = _store(state_dir)
        store.snapshot([], next_id=100, round_samples=RS)
        store.append_alloc("aaa", fn_offset=10, n_fn=8, round_samples=RS)
        store.close()
        report = streams.audit_state_dir(state_dir)
        assert _rules(report) == ["STR004"]

    def test_round_quantum_disagreement_fires_str005(self, state_dir):
        store = _store(state_dir)
        store.ensure_meta({"seed": 0, "round_samples": RS})
        store.append_alloc("aaa", fn_offset=0, n_fn=8,
                           round_samples=RS * 2)
        store.close()
        report = streams.audit_state_dir(state_dir)
        assert _rules(report) == ["STR005"]

    def test_orphan_deposit_fires_str006(self, state_dir):
        store = _store(state_dir)
        store.append_deposits([_dep(store, "ghost", 0, 8)])
        store.close()
        report = streams.audit_state_dir(state_dir)
        assert _rules(report) == ["STR006"]

    def test_grid_chain_gap_fires_str007(self, state_dir):
        store = _store(state_dir)
        edges = _edges()
        store.append_alloc("base", fn_offset=0, n_fn=1, round_samples=RS)
        store.append_grid("ep1", parent="base", epoch=1, edges=edges)
        store.append_alloc("ep1", fn_offset=1, n_fn=1, round_samples=RS)
        # refit claims epoch 3 but its parent's record says epoch 1
        store.append_grid("ep3", parent="ep1", epoch=3, edges=edges)
        store.append_alloc("ep3", fn_offset=2, n_fn=1, round_samples=RS)
        store.close()
        report = streams.audit_state_dir(state_dir)
        assert _rules(report) == ["STR007"]
        assert "contiguous" in report.violations[0].message

    def test_grid_after_alloc_fires_str007(self, state_dir):
        store = _store(state_dir)
        store.append_alloc("base", fn_offset=0, n_fn=1, round_samples=RS)
        store.append_alloc("ep1", fn_offset=1, n_fn=1, round_samples=RS)
        store.append_grid("ep1", parent="base", epoch=1, edges=_edges())
        store.close()
        report = streams.audit_state_dir(state_dir)
        assert _rules(report) == ["STR007"]
        v = report.violations[0]
        assert v.path.endswith("journal.bin") and v.line == 3
        assert "before" in v.message

    def test_duplicate_grid_disagreement_fires_str007(self, state_dir):
        store = _store(state_dir)
        edges = _edges()
        store.append_grid("ep1", parent="base", epoch=1, edges=edges)
        store.append_grid("ep1", parent="other", epoch=1, edges=edges)
        store.append_alloc("ep1", fn_offset=0, n_fn=1, round_samples=RS)
        store.close()
        report = streams.audit_state_dir(state_dir)
        assert _rules(report) == ["STR007"]
        assert "disagrees" in report.violations[0].message

    def test_snapshot_range_beyond_hwm_fires_str004(self, state_dir):
        store = _store(state_dir)
        store.snapshot([EntryState(
            chash="aaa", fn_offset=0, n_fn=16, round_samples=RS,
            s1=np.zeros(16, np.float32), s2=np.zeros(16, np.float32))],
            next_id=8, round_samples=RS)
        store.close()
        report = streams.audit_state_dir(state_dir)
        assert _rules(report) == ["STR004"]


class TestAuditCleanState:
    def test_clean_journal_audits_clean(self, state_dir):
        store = _store(state_dir)
        store.ensure_meta({"seed": 0, "round_samples": RS})
        store.append_alloc("aaa", fn_offset=0, n_fn=8, round_samples=RS)
        store.append_alloc("bbb", fn_offset=8, n_fn=4, round_samples=RS)
        store.append_deposits([_dep(store, "aaa", 0, 8),
                               _dep(store, "bbb", 0, 4),
                               _dep(store, "aaa", 1, 8)])
        store.close()
        report = streams.audit_state_dir(state_dir)
        assert report.ok, report.summary()
        assert report.streams == 2
        assert report.deposits_folded == 3

    def test_replayed_round_is_benign(self, state_dir):
        store = _store(state_dir)
        store.append_alloc("aaa", fn_offset=0, n_fn=8, round_samples=RS)
        store.append_deposits([_dep(store, "aaa", 0, 8),
                               _dep(store, "aaa", 0, 8),    # exact replay
                               _dep(store, "aaa", 1, 8)])
        store.close()
        report = streams.audit_state_dir(state_dir)
        assert report.ok, report.summary()
        assert report.deposits_folded == 2
        assert report.deposits_replayed == 1

    def test_torn_tail_is_reported_not_flagged(self, state_dir):
        store = _store(state_dir)
        store.append_alloc("aaa", fn_offset=0, n_fn=8, round_samples=RS)
        store.close()
        with open(store.journal_path, "ab") as f:
            f.write(b"ZMJ1\x99\x99torn-at-sigkill")
        report = streams.audit_state_dir(state_dir)
        assert report.ok, report.summary()
        assert report.truncated_tail_bytes > 0
        # auditing is read-only: the torn tail is still on disk
        report2 = streams.audit_state_dir(state_dir)
        assert report2.truncated_tail_bytes == report.truncated_tail_bytes

    def test_grid_epoch_chain_audits_clean(self, state_dir):
        """The planner's journal order — grid before alloc, epochs
        contiguous from a base stream — is exactly what STR007 admits,
        replays of a grid record included."""
        store = _store(state_dir)
        edges = _edges()
        store.append_alloc("base", fn_offset=0, n_fn=1, round_samples=RS)
        store.append_grid("ep1", parent="base", epoch=1, edges=edges)
        store.append_alloc("ep1", fn_offset=1, n_fn=1, round_samples=RS)
        store.append_grid("ep2", parent="ep1", epoch=2, edges=edges)
        # an agreeing duplicate is benign (replayed registration) — but
        # only before the alloc: after it, order itself is the breach
        store.append_grid("ep2", parent="ep1", epoch=2, edges=edges)
        store.append_alloc("ep2", fn_offset=2, n_fn=1, round_samples=RS)
        store.append_deposits([_dep(store, "ep2", 0, 1)])
        store.close()
        report = streams.audit_state_dir(state_dir)
        assert report.ok, report.summary()
        assert report.streams == 3

    def test_snapshot_plus_journal_chain(self, state_dir):
        store = _store(state_dir)
        store.snapshot([EntryState(
            chash="aaa", fn_offset=0, n_fn=8, round_samples=RS,
            s1=np.ones(8, np.float32), s2=np.ones(8, np.float32),
            n=2 * RS, rounds_done=2)], next_id=8, round_samples=RS)
        # post-snapshot deposits resume at the snapshot frontier
        store.append_deposits([_dep(store, "aaa", 2, 8)])
        store.close()
        report = streams.audit_state_dir(state_dir)
        assert report.ok, report.summary()
        assert report.deposits_folded == 1


class TestLiveEngineAudit:
    def test_engine_state_audits_clean_with_asserts_on(self, state_dir):
        from repro.core import harmonic_family
        from repro.service import IntegrationEngine
        from repro.service.api import IntegrationRequest

        streams.enable_asserts(True)
        try:
            with IntegrationEngine(round_samples=256, use_kernel=False,
                                   state_dir=state_dir) as engine:
                tickets = [
                    engine.submit(IntegrationRequest.make(
                        (harmonic_family(2, 2 + i % 2),), n_samples=512))
                    for i in range(4)]
                while any(engine.poll(t) is None for t in tickets):
                    engine.step()
        finally:
            streams.enable_asserts(None)
        report = streams.audit_state_dir(state_dir)
        assert report.ok, report.summary()
        assert report.streams > 0


class TestLiveHooks:
    def test_disjoint_allocation_passes(self):
        streams.assert_disjoint_allocation(
            [("a", 0, 8), ("b", 8, 4)], "c", 12, 8)

    def test_overlapping_allocation_raises_str001(self):
        with pytest.raises(AssertionError, match="STR001"):
            streams.assert_disjoint_allocation(
                [("a", 0, 8)], "b", 4, 8)

    def test_wave_consistency(self):
        streams.assert_wave_consistent({"a": [3, 4, 5], "b": [0]})
        with pytest.raises(AssertionError, match="STR002"):
            streams.assert_wave_consistent({"a": [0, 0, 1]})   # double
        with pytest.raises(AssertionError, match="STR002"):
            streams.assert_wave_consistent({"a": [0, 2]})      # gap

    def test_inflight_consistency(self):
        streams.assert_inflight_consistent("a", 0)
        with pytest.raises(AssertionError, match="retired twice"):
            streams.assert_inflight_consistent("a", -1)

    def test_find_overlaps(self):
        assert streams.find_overlaps(
            [("a", 0, 8), ("b", 8, 4), ("c", 20, 0)]) == []
        assert streams.find_overlaps(
            [("a", 0, 8), ("b", 4, 8)]) == [("a", "b")]

    def test_classify_round(self):
        assert streams.classify_round(3, 2) == "replay"
        assert streams.classify_round(3, 3) == "fold"
        assert streams.classify_round(3, 4) == "gap"

    def test_asserts_env_switch(self, monkeypatch):
        streams.enable_asserts(None)
        monkeypatch.delenv("REPRO_ANALYSIS_ASSERTS", raising=False)
        assert not streams.asserts_enabled()
        monkeypatch.setenv("REPRO_ANALYSIS_ASSERTS", "1")
        assert streams.asserts_enabled()
        streams.enable_asserts(False)
        try:
            assert not streams.asserts_enabled()
        finally:
            streams.enable_asserts(None)
