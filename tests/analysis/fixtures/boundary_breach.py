"""Seeded violation: imports jax.experimental outside the compat shims.

Linted by path only — never imported.  Expected findings:
BND001 at the two import lines and the attribute reference.
"""

from jax.experimental import pallas as pl                   # BND001
import jax.experimental.shard_map as jsm                    # BND001

import jax


def grid_of(x):
    return jax.experimental.pallas.num_programs(0) + pl.program_id(0) + jsm  # BND001
