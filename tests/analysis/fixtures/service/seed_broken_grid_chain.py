"""Seed a state dir whose importance-grid epoch chain is BROKEN (STR007).

CI's must-fail loop drives this through the real ``DurableStore`` API so
the journal is byte-for-byte what a buggy planner would have written,
then requires ``python -m repro.analysis --state-dir <dir>`` to exit
nonzero.  Two independent STR007 breaks are seeded:

* a **chain gap** — an epoch-3 grid whose parent carries the epoch-1
  record (a refit must extend its parent by exactly one);
* a **grid-after-alloc ordering flip** — a child stream alloc'd before
  its grid record hit the journal (replay could then fold deposits of a
  stream whose sampling map it does not know yet).

Usage: ``python seed_broken_grid_chain.py <state_dir>``
"""

import sys

import numpy as np

from repro.service.store import DurableStore


def seed(state_dir: str) -> None:
    store = DurableStore(state_dir, fsync=False)
    store.ensure_meta({"seed": 0, "round_samples": 4096})
    edges = np.linspace(0.0, 1.0, 5, dtype=np.float32)
    edges = np.broadcast_to(edges, (1, 2, 5)).copy()

    # base stream, then a well-formed epoch-1 child (grid BEFORE alloc)
    store.append_alloc("base:mc", fn_offset=0, n_fn=1, round_samples=4096)
    store.append_grid("epoch1:mc", parent="base:mc", epoch=1, edges=edges)
    store.append_alloc("epoch1:mc", fn_offset=1, n_fn=1, round_samples=4096)

    # break 1: the chain skips epoch 2 — a grid claiming epoch 3 whose
    # parent's record says epoch 1
    store.append_grid("epoch3:mc", parent="epoch1:mc", epoch=3, edges=edges)
    store.append_alloc("epoch3:mc", fn_offset=2, n_fn=1, round_samples=4096)

    # break 2: child alloc'd before its grid record was journaled
    store.append_alloc("late:mc", fn_offset=3, n_fn=1, round_samples=4096)
    store.append_grid("late:mc", parent="base:mc", epoch=1, edges=edges)
    store.close()


if __name__ == "__main__":
    if len(sys.argv) != 2:
        raise SystemExit("usage: seed_broken_grid_chain.py <state_dir>")
    seed(sys.argv[1])
