"""Seeded violation: an ad-hoc retry loop inside a ``service/`` path.
Linted by path only — never imported.  Expected findings: RES001 at the
``run_with_restarts`` import, the attribute reference, and the raw
backoff sleep (importing the fault_tolerance *module* is clean; only
the ad-hoc retry entry point and sleeps are fenced to resilience.py).
"""

from repro.distributed.fault_tolerance import run_with_restarts  # RES001

from repro.distributed import fault_tolerance as ft
from repro.obs import clock


def flaky_wave(body):
    ft.run_with_restarts(body, max_restarts=3)                   # RES001
    clock.sleep(0.25)                                            # RES001
    return run_with_restarts
