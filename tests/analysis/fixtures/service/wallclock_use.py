"""Seeded violation: direct wall-clock access inside a ``service/``
path.  Linted by path only — never imported.  Expected findings:
OBS001 at the ``time`` import and both ``time.*`` reads (the shimmed
``clock.monotonic`` call is clean).
"""

import time                                                 # OBS001

from repro.obs import clock


def wave_timer():
    t0 = time.monotonic()                                   # OBS001
    ok = clock.monotonic()                                  # clean: the shim
    return time.perf_counter() - t0, ok                     # OBS001
