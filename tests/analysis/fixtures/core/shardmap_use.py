"""Seeded violation: touches jax.shard_map directly instead of going
through repro.compat.  Linted by path only — never imported.  Expected
findings: BND002 at the import and the attribute reference.  (This file
sits under a ``core/`` segment, so it is also purity-scoped — it must
stay free of I/O and wall-clock to keep the findings exactly BND002.)
"""

from jax import shard_map                                   # BND002

import jax


def shard(f, mesh, specs):
    return jax.shard_map(f, mesh=mesh, in_specs=specs), shard_map  # BND002
