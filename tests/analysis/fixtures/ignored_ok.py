"""Escape-hatch fixture: every would-be violation on this page carries
an ``# analysis: ignore[RULE]`` annotation, so linting it must find
nothing.  Linted by path only — never imported.
"""

from jax.experimental import pallas as pl  # analysis: ignore[BND001]
from jax import shard_map                  # analysis: ignore[BND002]


def passthrough():
    return pl, shard_map
