"""Seeded violation: float64 on an accumulator path inside a
``kernels/`` path.  Linted by path only — never imported.  Expected
findings: F64001 at the jnp.float64 reference, the astype call and the
dtype kwarg.
"""

import jax.numpy as jnp


def eval_body(draw, p, f, dim):
    acc = jnp.zeros((16, 128), dtype="float64")             # F64001
    val = draw(0).astype("float64")                         # F64001
    return (acc + val).astype(jnp.float64)                  # F64001
