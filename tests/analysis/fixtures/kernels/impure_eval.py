"""Seeded violation: wall-clock, stateful RNG and host I/O inside a
``kernels/`` path.  Linted by path only — never imported.  Expected
findings: PUR001 at the two imports, the np.random use and the open()
call.
"""

import time                                                 # PUR001
import random                                               # PUR001

import numpy as np


def eval_body(draw, p, f, dim):
    jitter = np.random.uniform()                            # PUR001
    with open("/tmp/eval.log", "a") as fh:                  # PUR001
        fh.write(f"{time.time()} {random.random()}\n")
    return draw(0) + jitter
