"""Layer-1 AST lint: every seeded fixture fires its rule with the right
ID and location, the escape hatch silences, and the real tree is clean."""

import os

import pytest

from repro.analysis import boundary

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")
SRC_REPRO = os.path.join(os.path.dirname(__file__), "..", "..",
                         "src", "repro")


def _findings(relpath):
    return boundary.check_file(os.path.join(FIXTURES, relpath))


def _by_rule(violations):
    out = {}
    for v in violations:
        out.setdefault(v.rule, []).append(v)
    return out


class TestSeededFixtures:
    def test_boundary_breach_fires_bnd001(self):
        found = _findings("boundary_breach.py")
        rules = _by_rule(found)
        assert set(rules) == {"BND001"}
        lines = sorted(v.line for v in rules["BND001"])
        assert lines == [7, 8, 14], found
        assert all(v.path.endswith("boundary_breach.py") for v in found)

    def test_shardmap_use_fires_bnd002(self):
        found = _findings("core/shardmap_use.py")
        rules = _by_rule(found)
        assert set(rules) == {"BND002"}
        assert sorted(v.line for v in rules["BND002"]) == [8, 14], found

    def test_impure_eval_fires_pur001(self):
        found = _findings("kernels/impure_eval.py")
        rules = _by_rule(found)
        assert set(rules) == {"PUR001"}
        # imports of time and random, np.random use, open() call
        assert sorted(v.line for v in rules["PUR001"]) == [7, 8, 14, 15], found

    def test_f64_accum_fires_f64001(self):
        found = _findings("kernels/f64_accum.py")
        rules = _by_rule(found)
        assert set(rules) == {"F64001"}
        assert sorted(v.line for v in rules["F64001"]) == [11, 12, 13], found

    def test_wallclock_use_fires_obs001(self):
        found = _findings("service/wallclock_use.py")
        rules = _by_rule(found)
        assert set(rules) == {"OBS001"}
        # the time import and both time.* reads; the clock.monotonic()
        # call on line 14 must NOT fire
        assert sorted(v.line for v in rules["OBS001"]) == [7, 13, 15], found

    def test_adhoc_retry_fires_res001(self):
        found = _findings("service/adhoc_retry.py")
        rules = _by_rule(found)
        assert set(rules) == {"RES001"}
        # the run_with_restarts import, its attribute reference, and the
        # raw clock.sleep call; the module import on line 10 is clean
        assert sorted(v.line for v in rules["RES001"]) == [8, 15, 16], found

    def test_ignore_comment_silences(self):
        assert _findings("ignored_ok.py") == []

    def test_fixture_dir_scan_finds_all_rules(self):
        found = boundary.check_paths([FIXTURES])
        assert {v.rule for v in found} == {"BND001", "BND002", "PUR001",
                                           "F64001", "OBS001", "RES001"}


class TestRuleScoping:
    def test_purity_rules_only_fire_in_kernels_core(self):
        source = "import time\nx = open('f')\n"
        assert boundary.check_source(source, "repro/launch/driver.py") == []
        found = boundary.check_source(source, "repro/kernels/thing.py")
        assert [v.rule for v in found] == ["PUR001", "PUR001"]

    def test_np_float64_is_not_flagged(self):
        # host-side np.float64 (analytic references) is fine by design;
        # the rule targets jnp.float64 on device accumulator paths
        source = "import numpy as np\nx = np.float64(1.0)\n"
        assert boundary.check_source(source, "repro/core/refs.py") == []

    def test_shims_are_allowed_jax_experimental(self):
        source = "from jax.experimental import pallas as pl\n"
        assert boundary.check_source(
            source, "src/repro/kernels/pallas_compat.py") == []
        assert boundary.check_source(source, "src/repro/compat.py") == []
        assert boundary.check_source(
            source, "src/repro/service/engine.py") != []

    def test_configs_are_lint_exempt(self):
        # seed model-config data modules are excluded from tree scans
        found = boundary.check_paths(
            [os.path.join(SRC_REPRO, "configs")])
        assert found == []

    def test_ignore_comment_is_rule_specific(self):
        source = ("from jax.experimental import pallas "
                  "# analysis: ignore[BND002]\n")
        found = boundary.check_source(source, "repro/service/x.py")
        assert [v.rule for v in found] == ["BND001"]

    def test_obs001_only_fires_in_service_obs(self):
        source = "import time\nt = time.monotonic()\n"
        # standalone launchers and distributed/ are out of scope
        assert boundary.check_source(source, "repro/launch/driver.py") == []
        assert boundary.check_source(
            source, "repro/distributed/ft.py") == []
        found = boundary.check_source(source, "repro/service/engine.py")
        assert [v.rule for v in found] == ["OBS001", "OBS001"]
        found = boundary.check_source(source, "repro/obs/trace.py")
        assert [v.rule for v in found] == ["OBS001", "OBS001"]
        # kernels/core: the stricter PUR001 owns the import (OBS001
        # would be redundant there — they are not in its scope)
        found = boundary.check_source(source, "repro/kernels/body.py")
        assert [v.rule for v in found] == ["PUR001"]

    def test_clock_shim_is_allowed_time(self):
        source = "import time\nt = time.monotonic()\n"
        assert boundary.check_source(
            source, "src/repro/obs/clock.py") == []

    def test_res001_only_fires_in_service(self):
        source = ("from repro.distributed.fault_tolerance import "
                  "run_with_restarts\nclock.sleep(1.0)\n")
        # distributed/ and launchers keep their own loops; obs/ never
        # retries; only the service layer is fenced to the policy module
        assert boundary.check_source(source, "repro/distributed/ft.py") == []
        assert boundary.check_source(source, "repro/launch/driver.py") == []
        assert boundary.check_source(source, "repro/obs/export.py") == []
        found = boundary.check_source(source, "repro/service/engine.py")
        assert [v.rule for v in found] == ["RES001", "RES001"]

    def test_resilience_module_is_allowed_retries_and_sleep(self):
        source = ("from repro.distributed.fault_tolerance import "
                  "run_with_restarts\n_clock.sleep(0.5)\n")
        assert boundary.check_source(
            source, "src/repro/service/resilience.py") == []


@pytest.mark.parametrize("subtree", [
    "kernels", "core", "service", "obs", "launch", "analysis",
    "distributed"])
def test_real_tree_is_clean(subtree):
    path = os.path.join(SRC_REPRO, subtree)
    if not os.path.isdir(path):
        pytest.skip(f"no {subtree}/ in this tree")
    assert boundary.check_paths([path]) == []
