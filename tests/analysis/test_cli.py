"""The CLI CI gates on: exit codes, rule-ID + file:line output format,
and the clean-pass over the real tree."""

import os

import numpy as np

from repro.analysis.__main__ import main
from repro.analysis.violations import RULES
from repro.service.store import DurableStore

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")
SRC_REPRO = os.path.join(os.path.dirname(__file__), "..", "..",
                         "src", "repro")


def test_seeded_fixtures_exit_nonzero_with_rule_and_location(capsys):
    assert main([FIXTURES]) == 1
    out = capsys.readouterr().out
    assert "BND001" in out and "boundary_breach.py:7" in out
    assert "BND002" in out and "shardmap_use.py:8" in out
    assert "PUR001" in out and "impure_eval.py:7" in out
    assert "F64001" in out and "f64_accum.py:11" in out


def test_each_fixture_alone_exits_nonzero():
    for rel in ("boundary_breach.py", "core/shardmap_use.py",
                "kernels/impure_eval.py", "kernels/f64_accum.py"):
        assert main([os.path.join(FIXTURES, rel)]) == 1, rel


def test_clean_file_exits_zero():
    assert main([os.path.join(SRC_REPRO, "compat.py")]) == 0


def test_real_tree_and_contracts_exit_zero():
    # the acceptance gate: full default run (Layer 1 over the package
    # tree + Layer 2 over every registered capability combo) is clean
    assert main([]) == 0


def test_state_dir_audit_exit_codes(tmp_path, capsys):
    clean = str(tmp_path / "clean")
    store = DurableStore(clean, fsync=False)
    store.append_alloc("aaa", fn_offset=0, n_fn=4, round_samples=32)
    store.append_deposits([store.deposit_record(
        "aaa", 0, np.ones(4, np.float32), np.ones(4, np.float32), 32)])
    store.close()
    assert main([os.path.join(SRC_REPRO, "compat.py"),
                 "--state-dir", clean]) == 0

    gap = str(tmp_path / "gap")
    store = DurableStore(gap, fsync=False)
    store.append_alloc("aaa", fn_offset=0, n_fn=4, round_samples=32)
    store.append_deposits([store.deposit_record(
        "aaa", 5, np.ones(4, np.float32), np.ones(4, np.float32), 32)])
    store.close()
    capsys.readouterr()
    assert main([os.path.join(SRC_REPRO, "compat.py"),
                 "--state-dir", gap]) == 1
    out = capsys.readouterr().out
    assert "STR002" in out and "journal.bin:2" in out


def test_list_rules(capsys):
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in RULES:
        assert rule in out
