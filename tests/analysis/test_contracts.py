"""Layer-2 jaxpr contract checker: seeded-bad forms fire KCT rules with
the right ID and location, registration validates eagerly, and every
registered form passes under 100% of its advertised capability combos."""

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import contracts
from repro.kernels import registry
from repro.kernels.registry import KernelForm


# -- seeded-bad eval bodies (never registered with validate=True) -------------

def _good_body(draw, p, f, dim):
    val = p[f, 0] * draw(0)
    for d in range(1, dim):
        val = val * draw(d)
    return val


def _good_body_2(draw, p, f, dim):
    val = p[f, 0] + draw(0)
    for d in range(1, dim):
        val = val + draw(d)
    return val


def _int32_body(draw, p, f, dim):
    # deliberate: int32 is robustly non-f32 even with x64 disabled
    # (jnp.float64 would silently downgrade to float32 there)
    return (draw(0) * 0).astype(jnp.int32)


def _scalar_body(draw, p, f, dim):
    return jnp.sum(draw(0))


def _printing_body(draw, p, f, dim):
    jax.debug.print("tile {}", p[f, 0])
    return draw(0) * p[f, 0]


def _finite_only_body(draw, p, f, dim):
    # traces on finite packing but explodes under the compactified
    # wrapper's widened parameter block
    assert p.shape[1] == 1, "finite packing only"
    return draw(0) * p[f, 0]


def _form(body, name="fixture_form", **kw):
    kw.setdefault("samplers", ("mc",))
    kw.setdefault("supports_compactified", False)
    kw.setdefault("supports_adapted", False)
    return KernelForm(name=name, body=body,
                      pack_params=lambda fam: None,
                      n_cols=lambda dim: 1, **kw)


def _rules(violations):
    return {v.rule for v in violations}


class TestCheckForm:
    def test_good_body_is_clean(self):
        assert contracts.check_form(_form(_good_body)) == []

    def test_int32_accumulator_fires_kct002(self):
        found = contracts.check_form(_form(_int32_body))
        assert _rules(found) == {"KCT002"}
        assert all(v.path.endswith("test_contracts.py") for v in found)
        assert all(v.line > 0 for v in found)

    def test_scalar_output_fires_shape_contract(self):
        found = contracts.check_form(_form(_scalar_body))
        assert "KCT002" in _rules(found)
        assert any("shaped" in v.message for v in found)

    def test_debug_callback_fires_kct001(self):
        found = contracts.check_form(_form(_printing_body))
        assert "KCT001" in _rules(found)

    def test_broken_compactified_support_fires_kct004(self):
        form = _form(_finite_only_body, supports_compactified=True)
        found = contracts.check_form(form)
        assert "KCT004" in _rules(found)
        # the same body honestly advertised does not fire
        honest = _form(_finite_only_body, supports_compactified=False)
        assert contracts.check_form(honest) == []

    def test_broken_adapted_support_fires_kct006(self):
        """A body that cannot ignore the grid's packed edge columns must
        not advertise ``supports_adapted`` — the importance-map wrapper
        widens the parameter block exactly like compactification does."""
        form = _form(_finite_only_body, supports_adapted=True)
        found = contracts.check_form(form)
        assert "KCT006" in _rules(found)
        assert any("adapted" in v.message for v in found)
        # a well-behaved body really does compose with the map stage
        assert contracts.check_form(
            _form(_good_body, supports_adapted=True)) == []


class TestBucketUniformity:
    def test_mismatched_bucket_avals_fire_kct003(self):
        forms = [_form(_good_body, "good_a"), _form(_good_body_2, "good_b"),
                 _form(_int32_body, "bad_int32")]
        found = contracts.check_bucket_uniformity(forms)
        assert found and _rules(found) == {"KCT003"}
        assert all("bad_int32" in v.message for v in found)
        assert all("lax.switch" in v.message for v in found)

    def test_uniform_bucket_is_clean(self):
        forms = [_form(_good_body, "good_a"), _form(_good_body_2, "good_b")]
        assert contracts.check_bucket_uniformity(forms) == []


class TestEagerRegistration:
    def test_contract_breaking_form_raises_at_registration(self):
        bad = _form(_int32_body, "fixture_bad_int32")
        with pytest.raises(ValueError,
                           match="(?s)fixture_bad_int32.*KCT002"):
            registry.register_form(bad)
        # validation runs BEFORE the registry mutates
        assert "fixture_bad_int32" not in registry.names()
        assert registry.form("fixture_bad_int32") is None

    def test_bucket_mismatch_raises_naming_form_and_bucket(self):
        existing = [_form(_int32_body, "grandfathered_int32")]
        good = _form(_good_body, "fixture_newcomer")
        with pytest.raises(ValueError) as exc:
            contracts.validate_form_registration(good, existing)
        msg = str(exc.value)
        assert "fixture_newcomer" in msg
        assert "dim=" in msg and "sampler=" in msg
        assert "KCT003" in msg

    def test_validate_false_bypasses_the_gate(self):
        bad = _form(_int32_body, "fixture_unvalidated")
        try:
            registry.register_form(bad, validate=False)
            assert "fixture_unvalidated" in registry.names()
        finally:
            registry._FORMS.pop("fixture_unvalidated", None)
            registry._REGISTRY.pop("fixture_unvalidated", None)

    def test_good_form_registers_cleanly_against_builtins(self):
        form = _form(_good_body, "fixture_good_form",
                     samplers=("mc", "sobol"), supports_compactified=True)
        try:
            registry.register_form(form)
            assert "fixture_good_form" in registry.names()
        finally:
            registry._FORMS.pop("fixture_good_form", None)
            registry._REGISTRY.pop("fixture_good_form", None)
            registry._REGISTRY.pop("fixture_good_form@sobol", None)


class TestRegisteredForms:
    def test_real_registry_is_clean(self):
        assert contracts.check_registered_forms() == []

    def test_every_advertised_combo_is_covered(self):
        # 100% coverage: every (sampler, compactified, swept, adapted,
        # probe-dim) combo a form claims to support is traced by
        # check_form; swept probes the full sweep_cols name set (subsets
        # substitute fewer columns through identical machinery); adapted
        # is probed for non-swept combos only, mirroring the engine
        # (adapted streams are never swept)
        for form in registry.forms():
            combos = set(contracts._combos(form))
            assert combos, f"{form.name} advertises no workable combo"
            for sampler in form.samplers:
                for compact in (False, True):
                    if compact and not form.supports_compactified:
                        continue
                    for dim in contracts.PROBE_DIMS:
                        sweeps = [()]
                        if form.supports_swept:
                            sweeps.append(contracts._full_sweep(form, dim))
                        for swept in sweeps:
                            adapt_axis = ((False, True)
                                          if form.supports_adapted
                                          and not swept else (False,))
                            for adapted in adapt_axis:
                                if form.supports(dim=dim, sampler=sampler,
                                                 compactified=compact,
                                                 sweep=swept,
                                                 adapted=adapted):
                                    assert (sampler, compact, swept,
                                            adapted, dim) in combos

    def test_swept_combos_probed_for_sweepable_forms(self):
        # every builtin form declares sweep_cols, so each contributes
        # swept combos and check_form traces the KCT005 composition
        for form in registry.forms():
            if not form.supports_swept:
                continue
            swept_combos = [c for c in contracts._combos(form) if c[2]]
            assert swept_combos, f"{form.name} has sweep_cols but no " \
                                 "swept combo was enumerated"

    def test_builtin_forms_share_uniform_buckets(self):
        assert contracts.check_bucket_uniformity(registry.forms()) == []
