"""Logical-axis rule engine: fallback, retry pass, activation protection."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as sh


@pytest.fixture(scope="module")
def mesh():
    # a (1,1) two-axis mesh is enough: the rule engine only reads axis
    # names/sizes for divisibility, so use a fake-size wrapper
    return jax.make_mesh((1, 1), ("data", "model"))


class _FakeMesh:
    """Duck-typed mesh with arbitrary axis sizes (no devices needed)."""
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


M16 = _FakeMesh({"data": 16, "model": 16})
M3 = _FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_basic_mapping():
    spec = sh.logical_to_spec((128, 1024), ("embed", "mlp"), M16)
    assert spec == P("data", "model")


def test_divisibility_fallback():
    # 2 kv heads cannot shard over model=16
    spec = sh.logical_to_spec((4096, 2, 128), ("embed", "kv_heads", "head_dim"),
                              M16)
    assert spec == P("data",)


def test_param_retry_uses_head_dim():
    spec = sh.logical_to_spec((4096, 40, 128), ("embed", "heads", "head_dim"),
                              M16, param_retry=True)
    assert spec == P("data", None, "model")


def test_retry_skipped_for_activations():
    spec = sh.logical_to_spec((256, 4096, 40, 128),
                              ("batch", "seq", "heads", "head_dim"),
                              M16, param_retry=True)
    assert spec == P("data",)   # heads fallback, NO head_dim retry
    # tiny batch also falls back, still without retry
    spec = sh.logical_to_spec((8, 4096, 40, 128),
                              ("batch", "seq", "heads", "head_dim"),
                              M16, param_retry=True)
    assert spec == P()


def test_batch_multi_axis_multipod():
    spec = sh.logical_to_spec((256, 4096), ("batch", "seq"), M3)
    assert spec == P(("pod", "data"),)


def test_axis_used_once():
    # embedding: vocab takes model, embed takes data; nothing reused
    spec = sh.logical_to_spec((65280, 4096), ("vocab", "embed"), M16)
    assert spec == P("model", "data")


def test_constrain_noop_without_mesh():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert sh.constrain(x, ("batch", "embed")) is x


def test_tree_shardings_structure(mesh):
    ab = {"w": jax.ShapeDtypeStruct((4, 8), np.float32),
          "b": jax.ShapeDtypeStruct((8,), np.float32)}
    specs = {"w": ("embed", "mlp"), "b": ("mlp",)}
    out = sh.tree_shardings(ab, specs, mesh)
    assert set(out) == {"w", "b"}
    assert out["w"].mesh.axis_names == ("data", "model")


def test_is_axes_leaf():
    assert sh.is_axes_leaf(("a", None, "b"))
    assert sh.is_axes_leaf(())
    assert not sh.is_axes_leaf(("a", 3))
    assert not sh.is_axes_leaf("a")
