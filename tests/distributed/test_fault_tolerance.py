"""Watchdog + restart driver."""

import time

import pytest

from repro.distributed.fault_tolerance import (StepWatchdog, WorkQueue,
                                               run_with_restarts)


def test_watchdog_flags_straggler():
    wd = StepWatchdog(threshold=5.0, warmup=3)
    for _ in range(6):
        with wd:
            time.sleep(0.01)
    with wd:
        time.sleep(0.2)   # 20x the median
    assert wd.straggler_count == 1
    ev = wd.events[0]
    assert ev.duration > 5 * ev.median


def test_watchdog_quiet_on_uniform_steps():
    wd = StepWatchdog(threshold=3.0, warmup=2)
    for _ in range(10):
        with wd:
            time.sleep(0.005)
    assert wd.straggler_count == 0


def test_run_with_restarts_recovers():
    calls = []

    def body(attempt):
        calls.append(attempt)
        if attempt < 2:
            raise RuntimeError("boom")
        return "done"

    restarts = []
    out = run_with_restarts(body, max_restarts=3,
                            on_restart=lambda a, e: restarts.append(a))
    assert out == "done"
    assert calls == [0, 1, 2]
    assert restarts == [0, 1]


def test_run_with_restarts_exhausts():
    def body(attempt):
        raise ValueError("always")
    with pytest.raises(ValueError):
        run_with_restarts(body, max_restarts=2)


def test_work_queue_all_chunks_covered_after_failures():
    q = WorkQueue(total_samples=1000, chunk=128)
    done = []
    fail_next = True
    while not q.finished:
        item = q.take()
        if item is None:
            break
        t, c = item
        if fail_next:
            q.fail(t)
            fail_next = False
        else:
            q.complete(t)
            done.append(c)
            fail_next = True
    starts = sorted(s for s, _ in done)
    assert starts == [0, 128, 256, 384, 512, 640, 768, 896]
    assert sum(n for _, n in done) == 1000
