"""8-fake-device program: sharded MC statistically valid + mesh-invariant
sum merging; compressed_psum sanity. Run by test_multidevice.py."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "..",
                                "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import (MultiFunctionSpec, ZMCMultiFunctions,
                        harmonic_analytic, harmonic_family)

mesh = jax.make_mesh((4, 2), ("data", "model"))
spec = MultiFunctionSpec.from_families([harmonic_family(10, 4)])
zm = ZMCMultiFunctions(spec, n_samples=200_000, seed=5, mesh=mesh)
r = zm.evaluate(num_trials=2)
exact = harmonic_analytic(10, 4)
pulls = np.abs(r.trial_mean - exact) / np.maximum(r.stderrs.mean(0), 1e-12)
assert np.all(pulls < 5.0), pulls

# mesh-shape invariance of the estimate (same counters, same totals)
mesh2 = jax.make_mesh((2, 4), ("data", "model"))
zm2 = ZMCMultiFunctions(spec, n_samples=200_000, seed=5, mesh=mesh2)
r2 = zm2.evaluate(num_trials=1)
# sample partition differs (4 vs 2 sample shards) -> statistically equal
assert np.all(np.abs(r2.means[0] - r.means[0])
              <= 6 * np.maximum(r.stderrs.mean(0), 1e-12))

# compressed psum inside shard_map
from repro.compat import shard_map
from repro.distributed.compression import compressed_psum

x = jnp.arange(32, dtype=jnp.float32).reshape(8, 4) / 7.0


def f(xl):
    return compressed_psum(xl, "data")


got = shard_map(f, mesh=mesh, in_specs=P("data", None),
                out_specs=P("data", None))(x)
ref = np.tile(np.asarray(x).reshape(4, 2, 4).sum(0), (4, 1)).reshape(8, 4)
# int8 over shared scale: tolerance = scale
tol = float(np.abs(x).max()) / 127 * 4 + 1e-5
assert np.abs(np.asarray(got) - ref).max() <= tol
print("PROG_OK")

# fused-bucket kernels inside shard_map: the whole spec in one
# interpret-mode pallas_call per dim bucket, sharded functions x samples,
# matching the single-device fused path on the valid rows
from repro.core import gaussian_family
from repro.kernels import template as _template

fspec = MultiFunctionSpec.from_families(
    [harmonic_family(10, 4), harmonic_family(6, 2), gaussian_family(5, 4)])
_template.reset_launch_count()
zk = ZMCMultiFunctions(fspec, n_samples=32768, seed=5, mesh=mesh,
                       use_kernel=True)
rk = zk.evaluate(num_trials=1)
assert _template.launch_count() == 2, _template.launch_count()  # dims {2,4}
zs = ZMCMultiFunctions(fspec, n_samples=32768, seed=5, use_kernel=True)
rs = zs.evaluate(num_trials=1)
# same counters; only the psum association order differs from the
# single-device chain -> agreement at f32 rounding level, far below stderr
assert np.abs(rk.means - rs.means).max() < 1e-4, \
    np.abs(rk.means - rs.means).max()
print("PROG_OK_FUSED")

# exact sample split: n not divisible by the 4 data shards must still
# draw exactly n counters (the service cache folds consecutive windows,
# so a rounded-up shard range would overlap the next window)
from repro.core import rng as _rng
from repro.kernels.mc_eval import multi as _multi

_plan = _multi.plan_spec(MultiFunctionSpec.from_families(
    [harmonic_family(10, 3)]))
_key = _rng.fold_key(1, 0)
_n = 4098                              # per_shard=1025 -> 2 masked samples
_sh = _multi.sharded_eval_plan(_plan, _n, _key, mesh)
_ref = _multi.eval_plan(_plan, _n, _key)
assert float(_sh[0].n) == _n
assert np.allclose(np.asarray(_sh[0].s1), np.asarray(_ref[0].s1),
                   rtol=2e-6, atol=1e-4)
print("PROG_OK_EXACT_SPLIT")

# distributed ZMCNormal: strata over 'model', samples over 'data'
import jax.numpy as _jnp
from repro.core import ZMCNormal
f = lambda x: _jnp.sin(x[..., 0]) * _jnp.cos(x[..., 1])
zn = ZMCNormal(f, [[0, np.pi], [0, np.pi / 2]], seed=3, splits_per_dim=4,
               n_per_stratum=512, depth=4, k_split=8, mesh=mesh)
res = zn.evaluate(num_trials=2)
assert abs(res.integral - 2.0) < 0.02, res
print("PROG_OK_NORMAL")
