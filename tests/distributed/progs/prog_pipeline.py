"""8-fake-device program: GPipe pipeline == sequential composition."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "..",
                                "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.pipeline import pipeline_apply

mesh = jax.make_mesh((4, 2), ("pod", "data"))
n_stages, m, mb, d = 4, 6, 3, 16
key = jax.random.key(0)
w = jax.random.normal(key, (n_stages, d, d)) * (0.5 / np.sqrt(d))
b = jax.random.normal(jax.random.key(1), (n_stages, d)) * 0.1
x = jax.random.normal(jax.random.key(2), (m, mb, d))


def stage_fn(p, xin):
    wi, bi = p
    return jnp.tanh(xin @ wi + bi)


out = pipeline_apply(stage_fn, (w, b), x, mesh, axis="pod")

ref = np.asarray(x)
for s in range(n_stages):
    ref = np.tanh(ref @ np.asarray(w[s]) + np.asarray(b[s]))
np.testing.assert_allclose(np.asarray(out), ref, atol=1e-5)
print("PROG_OK")
