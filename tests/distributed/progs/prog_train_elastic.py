"""8-fake-device program: multi-axis-mesh training + elastic resume.

1. Train a reduced model 6 steps on a (2,2,2) pod mesh with checkpoints.
2. Restore the checkpoint onto a (4,2) mesh and onto a 1-device path and
   verify the next-step loss matches bit-for-bit-ish (same data stream).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "..",
                                "src"))
import tempfile

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.launch.train import (TrainHParams, train_loop)

cfg = reduced(get_config("stablelm_3b"))
hp_kwargs = {}

tmp = tempfile.mkdtemp()
mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
state, losses, _ = train_loop(cfg, __import__("dataclasses").replace(
    TrainHParams(), total_steps=6, warmup_steps=1, grad_accum=2),
    batch=8, seq=32, steps=6, mesh=mesh3, ckpt_dir=tmp, ckpt_every=3,
    log_every=100)

# resume on a DIFFERENT mesh from step 6 and keep training
mesh2 = jax.make_mesh((4, 2), ("data", "model"))
state2, losses2, _ = train_loop(cfg, __import__("dataclasses").replace(
    TrainHParams(), total_steps=8, warmup_steps=1, grad_accum=2),
    batch=8, seq=32, steps=8, mesh=mesh2, ckpt_dir=tmp, ckpt_every=100,
    log_every=100)
assert len(losses2) == 2, len(losses2)   # resumed from step 6

# exactness of the restore itself: restored params == checkpointed params
from repro.distributed import checkpoint as ckpt
from repro.launch.train import abstract_train_state, train_state_specs
from repro.distributed.sharding import tree_shardings
from repro.models.model import Model
import jax as _jax

model = Model(cfg)
hp = __import__("dataclasses").replace(TrainHParams(), total_steps=8,
                                       warmup_steps=1, grad_accum=2)
abstract = abstract_train_state(model, hp)
re1, _ = ckpt.restore(tmp, 6, abstract)                       # host arrays
sh2 = tree_shardings(abstract, train_state_specs(model, hp), mesh2)
re2, _ = ckpt.restore(tmp, 6, abstract, shardings=sh2)        # on mesh2
for a, b in zip(_jax.tree.leaves(re1), _jax.tree.leaves(re2)):
    np.testing.assert_array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))

# uninterrupted single-mesh oracle: trajectories agree to cross-mesh
# reduction-order noise (steps 0..5 ran on a different mesh)
state3, losses3, _ = train_loop(cfg, hp, batch=8, seq=32, steps=8,
                                mesh=mesh2, ckpt_dir=None, log_every=100)
np.testing.assert_allclose(losses2[-1], losses3[-1], rtol=2e-2)
print("PROG_OK")
