"""Checkpointing: roundtrip, async writer, GC, latest_step."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import checkpoint as ckpt


def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "params": {"w": jax.random.normal(k, (4, 8)),
                   "b": jnp.zeros((8,), jnp.bfloat16)},
        "opt": {"mu": {"w": jnp.ones((4, 8))}},
        "step": jnp.int32(7),
    }


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t, extra={"data_step": 3})
    restored, manifest = ckpt.restore(str(tmp_path), 7, t)
    assert manifest["extra"]["data_step"] == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_step(tmp_path):
    assert ckpt.latest_step(str(tmp_path)) is None
    for s in (5, 10, 20):
        ckpt.save(str(tmp_path), s, _tree())
    assert ckpt.latest_step(str(tmp_path)) == 20


def test_missing_leaf_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.zeros(3)})
    with pytest.raises(KeyError):
        ckpt.restore(str(tmp_path), 1, {"b": jnp.zeros(3)})


def test_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), 1, {"a": jnp.zeros(4)})


def test_async_writer_and_gc(tmp_path):
    w = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        w.save(s, _tree(s))
    w.close()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_3", "step_4"]
    restored, _ = ckpt.restore(str(tmp_path), 4, _tree())
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]),
        np.asarray(_tree(4)["params"]["w"]))


def test_no_tmp_dirs_left(tmp_path):
    ckpt.save(str(tmp_path), 3, _tree())
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))
