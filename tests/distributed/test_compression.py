"""int8 error-feedback gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the optional 'test' extra (pip install "
           "hypothesis); the rest of the suite runs without it")
from hypothesis import given, settings, strategies as st

from repro.distributed import compression as C


def test_quantize_bounds():
    x = jax.random.normal(jax.random.key(0), (128,)) * 5
    q, s = C.quantize(x)
    assert q.dtype == jnp.int8
    deq = C.dequantize(q, s)
    assert float(jnp.max(jnp.abs(deq - x))) <= float(s) * 0.5 + 1e-6


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_quant_error_scales_with_amax(seed):
    x = jax.random.normal(jax.random.key(seed), (256,))
    q, s = C.quantize(x)
    err = jnp.abs(C.dequantize(q, s) - x)
    assert float(err.max()) <= float(jnp.abs(x).max()) / 127 * 0.5 + 1e-7


def test_error_feedback_unbiased_accumulation():
    """Sum of EF-compressed grads tracks the sum of true grads."""
    key = jax.random.key(1)
    g_true = jax.random.normal(key, (50, 64)) * 0.1
    err = jnp.zeros((64,))
    total_hat = jnp.zeros((64,))
    for i in range(50):
        ghat, err = C.ef_compress(g_true[i], err)
        total_hat = total_hat + ghat
    total = g_true.sum(0)
    # residual bounded by one quantisation step, NOT accumulating
    resid = np.abs(np.asarray(total_hat + err - total))
    assert resid.max() < 1e-4
    rel = np.linalg.norm(np.asarray(total_hat - total)) / \
        np.linalg.norm(np.asarray(total))
    assert rel < 0.05


def test_compress_tree_shapes():
    params = {"a": jnp.ones((3, 4)), "b": jnp.zeros((7,))}
    errs = C.init_error_tree(params)
    g = jax.tree.map(lambda p: p * 0.3, params)
    ghat, new_err = C.compress_tree(g, errs)
    assert jax.tree.structure(ghat) == jax.tree.structure(g)
    for a, b in zip(jax.tree.leaves(ghat), jax.tree.leaves(g)):
        assert a.shape == b.shape and a.dtype == b.dtype
