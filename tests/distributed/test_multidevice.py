"""Multi-device semantics via subprocess (8 forced host devices).

Each prog_*.py asserts internally and prints PROG_OK; running them in
subprocesses keeps this pytest process on 1 device.
"""

import os
import subprocess
import sys

import pytest

PROG_DIR = os.path.join(os.path.dirname(__file__), "progs")


def _run(prog: str, timeout: int = 420):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, os.path.join(PROG_DIR, prog)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"{prog} failed:\n{out.stdout}\n{out.stderr}"
    assert "PROG_OK" in out.stdout, out.stdout


@pytest.mark.slow
def test_sharded_mc_and_compressed_psum():
    _run("prog_sharded_mc.py")


@pytest.mark.slow
def test_train_elastic_resume():
    _run("prog_train_elastic.py")


@pytest.mark.slow
def test_pipeline_parallel():
    _run("prog_pipeline.py")
