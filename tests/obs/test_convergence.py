"""Convergence log: bounded stderr-vs-rounds trajectories per stream."""

import pytest

from repro.obs.convergence import ConvergenceLog, TrajectoryPoint


def _record_n(log, chash, n, *, start=1):
    for r in range(start, start + n):
        log.record(chash, rounds_done=r, n=r * 4096,
                   stderr_max=1.0 / r ** 0.5, stderr_mean=0.5 / r ** 0.5)


class TestBasics:
    def test_records_every_round_at_stride_one(self):
        log = ConvergenceLog()
        _record_n(log, "s", 5)
        traj = log.trajectory("s")
        assert [p.rounds_done for p in traj] == [1, 2, 3, 4, 5]
        assert traj[0] == TrajectoryPoint(1, 4096, 1.0, 0.5)

    def test_unknown_stream_is_empty(self):
        assert ConvergenceLog().trajectory("nope") == []

    def test_streams_listing(self):
        log = ConvergenceLog()
        _record_n(log, "a", 2)
        _record_n(log, "b", 1)
        assert sorted(log.streams()) == ["a", "b"]

    def test_min_max_points_enforced(self):
        with pytest.raises(ValueError):
            ConvergenceLog(max_points=2)


class TestDecimation:
    def test_overflow_halves_and_doubles_stride(self):
        log = ConvergenceLog(max_points=8)
        _record_n(log, "s", 9)
        assert log.stride("s") == 2
        pts = log.trajectory("s")
        # thinned skeleton keeps every other retained point
        assert [p.rounds_done for p in pts] == [1, 3, 5, 7, 9]

    def test_memory_stays_bounded(self):
        log = ConvergenceLog(max_points=16)
        _record_n(log, "s", 10_000)
        pts = log.trajectory("s")
        assert len(pts) <= 17          # retained skeleton + frontier
        assert log.stride("s") >= 512

    def test_frontier_is_always_reported(self):
        # off-stride latest record must still end the trajectory
        log = ConvergenceLog(max_points=8)
        _record_n(log, "s", 10)        # stride now 2; round 10 off-stride
        pts = log.trajectory("s")
        assert pts[-1].rounds_done == 10
        _record_n(log, "s", 1, start=11)
        assert log.trajectory("s")[-1].rounds_done == 11

    def test_rounds_strictly_increase_after_any_decimation(self):
        log = ConvergenceLog(max_points=8)
        _record_n(log, "s", 1000)
        rounds = [p.rounds_done for p in log.trajectory("s")]
        assert rounds == sorted(set(rounds))
        assert rounds[-1] == 1000

    def test_streams_decimate_independently(self):
        log = ConvergenceLog(max_points=8)
        _record_n(log, "big", 100)
        _record_n(log, "small", 3)
        assert log.stride("big") > 1
        assert log.stride("small") == 1
        assert len(log.trajectory("small")) == 3


class TestSnapshot:
    def test_snapshot_shape(self):
        log = ConvergenceLog()
        _record_n(log, "s", 2)
        snap = log.snapshot()
        assert snap["s"]["stride"] == 1
        assert snap["s"]["points"] == [[1, 4096, 1.0, 0.5],
                                       [2, 8192, pytest.approx(1 / 2 ** .5),
                                        pytest.approx(0.5 / 2 ** .5)]]

    def test_snapshot_is_json_able(self):
        import json
        log = ConvergenceLog(max_points=4)
        _record_n(log, "s", 50)
        json.dumps(log.snapshot())
