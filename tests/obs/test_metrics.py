"""Metrics registry: counters/gauges/histograms and both expositions.

The exposition formats are load-bearing (a real Prometheus scrapes
``/metrics``; ``BENCH_7.json`` embeds ``snapshot()``), so the text
rendering is asserted verbatim, not just structurally.
"""

import threading

import pytest

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               service_metrics)


class TestCounter:
    def test_inc_and_value(self):
        c = Counter("x_total", "help")
        c.inc()
        c.inc(2.5)
        assert c.value() == 3.5

    def test_counters_only_go_up(self):
        c = Counter("x_total", "")
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_labelled_counter(self):
        c = Counter("x_total", "", labelnames=("outcome",))
        c.inc(outcome="hit")
        c.inc(outcome="hit")
        c.inc(outcome="miss")
        assert c.value(outcome="hit") == 2
        assert c.value(outcome="miss") == 1

    def test_missing_or_extra_labels_raise(self):
        c = Counter("x_total", "", labelnames=("outcome",))
        with pytest.raises(ValueError):
            c.inc()
        with pytest.raises(ValueError):
            c.inc(outcome="hit", extra="nope")

    def test_concurrent_increments_are_exact(self):
        # the CI gate compares counters *exactly* against engine
        # observables, so lost increments are a real failure mode
        c = Counter("x_total", "")
        n, per = 8, 1000

        def work():
            for _ in range(per):
                c.inc()

        threads = [threading.Thread(target=work) for _ in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert c.value() == n * per


class TestGauge:
    def test_set_inc_dec(self):
        g = Gauge("depth", "")
        g.set(5)
        g.inc(2)
        g.dec(4)
        assert g.value() == 3

    def test_gauge_goes_negative(self):
        g = Gauge("depth", "")
        g.dec(2)
        assert g.value() == -2


class TestHistogram:
    def test_observe_count_sum(self):
        h = Histogram("lat", "", buckets=(0.1, 1.0))
        h.observe(0.05)
        h.observe(0.5)
        h.observe(5.0)
        assert h.count() == 3
        assert h.sum() == pytest.approx(5.55)

    def test_cumulative_bucket_semantics(self):
        h = Histogram("lat", "", buckets=(0.1, 1.0))
        for v in (0.05, 0.5, 5.0):
            h.observe(v)
        samples = {(name, labels.get("le")): value
                   for name, labels, value in h._samples()
                   if name == "lat_bucket"}
        assert samples[("lat_bucket", "0.1")] == 1
        assert samples[("lat_bucket", "1")] == 2       # cumulative
        assert samples[("lat_bucket", "+Inf")] == 3

    def test_labelled_histogram(self):
        h = Histogram("lat", "", labelnames=("stage",), buckets=(1.0,))
        h.observe(0.5, stage="plan")
        h.observe(2.0, stage="deposit")
        assert h.count(stage="plan") == 1
        assert h.count(stage="deposit") == 1
        assert h.count(stage="launch") == 0


class TestRegistry:
    def test_getters_are_idempotent(self):
        reg = MetricsRegistry()
        a = reg.counter("x_total", "h")
        b = reg.counter("x_total", "h")
        assert a is b

    def test_kind_conflict_raises(self):
        reg = MetricsRegistry()
        reg.counter("x_total")
        with pytest.raises(TypeError):
            reg.gauge("x_total")
        with pytest.raises(TypeError):
            reg.histogram("x_total")

    def test_label_conflict_raises(self):
        reg = MetricsRegistry()
        reg.counter("x_total", labelnames=("a",))
        with pytest.raises(ValueError):
            reg.counter("x_total", labelnames=("b",))

    def test_prometheus_text_exposition_verbatim(self):
        reg = MetricsRegistry()
        c = reg.counter("zmc_x_total", "things", labelnames=("kind",))
        c.inc(3, kind="a")
        g = reg.gauge("zmc_depth", "how deep")
        g.set(2)
        text = reg.render_prometheus()
        assert text == (
            "# HELP zmc_depth how deep\n"
            "# TYPE zmc_depth gauge\n"
            "zmc_depth 2\n"
            "# HELP zmc_x_total things\n"
            "# TYPE zmc_x_total counter\n"
            'zmc_x_total{kind="a"} 3\n')

    def test_prometheus_histogram_exposition(self):
        reg = MetricsRegistry()
        h = reg.histogram("zmc_lat", "", buckets=(0.5, 1.0))
        h.observe(0.25)
        h.observe(0.75)
        lines = reg.render_prometheus().splitlines()
        assert 'zmc_lat_bucket{le="0.5"} 1' in lines
        assert 'zmc_lat_bucket{le="1"} 2' in lines
        assert 'zmc_lat_bucket{le="+Inf"} 2' in lines
        assert "zmc_lat_sum 1" in lines
        assert "zmc_lat_count 2" in lines

    def test_snapshot_shapes(self):
        reg = MetricsRegistry()
        reg.counter("plain_total").inc(2)
        reg.counter("split_total", labelnames=("k",)).inc(1, k="x")
        reg.histogram("lat", buckets=(1.0,)).observe(0.5)
        snap = reg.snapshot()
        assert snap["plain_total"] == {"type": "counter", "value": 2.0}
        assert snap["split_total"]["value"] == {"x": 1.0}
        assert snap["lat"]["value"]["count"] == 1


class TestServiceMetrics:
    def test_canonical_names_all_declared(self):
        reg = MetricsRegistry()
        handles = service_metrics(reg)
        names = {m.name for m in handles.values()}
        for expected in ("zmc_kernel_launches_total",
                         "zmc_fallback_rounds_total",
                         "zmc_cache_requests_total",
                         "zmc_warm_zero_launch_total",
                         "zmc_requests_submitted_total",
                         "zmc_requests_served_total",
                         "zmc_waves_total", "zmc_wave_restarts_total",
                         "zmc_straggler_events_total",
                         "zmc_deposit_rounds_total",
                         "zmc_inflight_rounds", "zmc_pending_requests",
                         "zmc_wave_seconds", "zmc_stage_seconds",
                         "zmc_wave_rounds", "zmc_bucket_rounds_total",
                         "zmc_wal_bytes_total", "zmc_wal_fsync_seconds",
                         "zmc_wal_commits_total"):
            assert expected in names, expected

    def test_redeclaration_returns_same_handles(self):
        reg = MetricsRegistry()
        a = service_metrics(reg)
        b = service_metrics(reg)
        assert all(a[k] is b[k] for k in a)
