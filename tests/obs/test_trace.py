"""Tracer + trace artifact: Chrome-trace events, crash-tolerant JSONL,
and the fake clock driving deterministic timestamps."""

import json

import pytest

from repro.obs import clock
from repro.obs.trace import (STAGES, NULL, JsonlWriter, NullTracer, Tracer,
                             load_trace, span_totals)


@pytest.fixture
def fake_clock():
    """A controllable second-counter driving monotonic/wall readings."""
    state = {"t": 100.0}

    def advance(dt):
        state["t"] += dt

    clock.set_clock(lambda: state["t"])
    yield advance
    clock.set_clock(None)


class TestStages:
    def test_six_stages_in_causal_order(self):
        assert STAGES == ("plan", "launch", "device_execute", "transfer",
                          "deposit", "wal_commit")


class TestNullTracer:
    def test_disabled_and_shared_span(self):
        assert NullTracer.enabled is False
        s1, s2 = NULL.span("a"), NULL.span("b", x=1)
        assert s1 is s2            # one shared no-op context manager
        with s1:
            pass
        assert NULL.instant("x") is None


class TestTracer:
    def test_span_emits_complete_event(self, fake_clock):
        events = []
        tracer = Tracer(events.append)
        with tracer.span("launch", wave=3, items=7):
            fake_clock(0.002)
        (ev,) = events
        assert ev["ph"] == "X"
        assert ev["name"] == "launch"
        assert ev["dur"] == 2000            # µs, from the fake clock
        assert ev["ts"] == int(100.0 * 1e6)
        assert ev["args"] == {"wave": 3, "items": 7}

    def test_instant_event(self, fake_clock):
        events = []
        tracer = Tracer(events.append)
        tracer.instant("wave_restart", wave=5, streams=["abc"])
        (ev,) = events
        assert ev["ph"] == "i"
        assert ev["s"] == "t"
        assert ev["args"] == {"wave": 5, "streams": ["abc"]}

    def test_multiple_sinks_all_receive(self, fake_clock):
        a, b = [], []
        tracer = Tracer(a.append)
        tracer.add_sink(b.append)
        with tracer.span("plan"):
            pass
        assert len(a) == len(b) == 1

    def test_span_emits_on_exception(self, fake_clock):
        events = []
        tracer = Tracer(events.append)
        with pytest.raises(RuntimeError):
            with tracer.span("deposit"):
                raise RuntimeError("wave died")
        assert events and events[0]["name"] == "deposit"


class TestJsonlWriter:
    def test_round_trip(self, tmp_path, fake_clock):
        path = str(tmp_path / "trace.json")
        writer = JsonlWriter(path)
        tracer = Tracer(writer)
        with tracer.span("plan", wave=0):
            fake_clock(0.001)
        tracer.instant("straggler", wave=0)
        tracer.close()
        events = load_trace(path)
        assert [e["name"] for e in events] == ["plan", "straggler"]
        assert writer.n_events == 2

    def test_unclosed_file_still_loads(self, tmp_path, fake_clock):
        # the crash-tolerance property: a SIGKILLed process leaves a
        # headless array that load_trace (and Perfetto) accept
        path = str(tmp_path / "trace.json")
        writer = JsonlWriter(path)
        tracer = Tracer(writer)
        with tracer.span("launch"):
            pass
        writer.flush()                      # no close(): simulated crash
        events = load_trace(path)
        assert [e["name"] for e in events] == ["launch"]

    def test_loads_as_plain_json_after_patching_tail(self, tmp_path,
                                                     fake_clock):
        # what Perfetto effectively does: tolerate the trailing comma
        path = str(tmp_path / "trace.json")
        tracer = Tracer(JsonlWriter(path))
        with tracer.span("transfer"):
            pass
        tracer.close()
        text = open(path).read().strip().rstrip(",") + "]"
        assert json.loads(text)[0]["name"] == "transfer"

    def test_closed_array_loads_too(self, tmp_path):
        path = str(tmp_path / "t.json")
        with open(path, "w") as f:
            json.dump([{"ph": "X", "name": "plan", "dur": 5}], f)
        assert load_trace(path)[0]["name"] == "plan"

    def test_empty_trace(self, tmp_path):
        path = str(tmp_path / "t.json")
        JsonlWriter(path).close()
        assert load_trace(path) == []


class TestSpanTotals:
    def test_aggregates_complete_events_only(self):
        events = [
            {"ph": "X", "name": "launch", "dur": 2_000_000},
            {"ph": "X", "name": "launch", "dur": 1_000_000},
            {"ph": "X", "name": "deposit", "dur": 500_000},
            {"ph": "i", "name": "straggler"},
        ]
        totals = span_totals(events)
        assert totals == {"launch": 3.0, "deposit": 0.5}


class TestObservabilityBundle:
    def test_disabled_is_null_traced_but_counted(self):
        from repro.obs import Observability
        obs = Observability.disabled()
        assert obs.tracing is False
        assert obs.record_convergence is False
        obs.m["launches"].inc(3)
        assert obs.m["launches"].value() == 3

    def test_enabled_spans_feed_stage_histogram(self, fake_clock):
        from repro.obs import Observability
        events = []
        obs = Observability.enabled(sinks=(events.append,))
        assert obs.tracing is True and obs.record_convergence is True
        with obs.span("deposit", items=4):
            fake_clock(0.01)
        with obs.span("not_a_stage"):
            fake_clock(0.01)
        # trace got both; the per-stage histogram only the pipeline stage
        assert [e["name"] for e in events] == ["deposit", "not_a_stage"]
        assert obs.m["stage_seconds"].count(stage="deposit") == 1
        assert obs.m["stage_seconds"].sum(stage="deposit") == \
            pytest.approx(0.01)

    def test_enabled_writes_trace_file(self, tmp_path, fake_clock):
        from repro.obs import Observability
        path = str(tmp_path / "trace.json")
        obs = Observability.enabled(trace_path=path)
        obs.event("wave_restart", wave=1)
        obs.close()
        assert [e["name"] for e in load_trace(path)] == ["wave_restart"]


class TestClockShim:
    def test_fake_clock_drives_all_three_readings(self, fake_clock):
        t0 = (clock.monotonic(), clock.monotonic_ns(), clock.wall())
        assert t0 == (100.0, int(100.0 * 1e9), 100.0)
        fake_clock(1.5)
        assert clock.monotonic() == 101.5
        assert clock.wall() == 101.5

    def test_real_clock_restored(self):
        clock.set_clock(None)
        a = clock.monotonic()
        b = clock.monotonic()
        assert b >= a
        assert clock.monotonic_ns() > 0
