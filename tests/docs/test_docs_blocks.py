"""Docs stay honest: every fenced python block in docs/*.md must parse,
and every import it names must resolve against the current tree.

The architecture documents quote real entry points (``from repro.service
import SweepRequest``, ``registry.lookup(...)``, rule-id tables...); a
rename that orphans a doc snippet should fail CI, not wait for a reader
to trip over it.  Full execution is out of scope — blocks may launch
kernels or spin up engines — so only the import statements of each
block are executed; the rest is syntax-checked via ``ast.parse``.
"""

import ast
import pathlib
import re

import pytest

DOCS = pathlib.Path(__file__).resolve().parents[2] / "docs"
_FENCE = re.compile(r"```python\n(.*?)```", re.S)


def _blocks():
    out = []
    for md in sorted(DOCS.glob("*.md")):
        for i, m in enumerate(_FENCE.finditer(md.read_text())):
            out.append(pytest.param(m.group(1), id=f"{md.name}:block{i}"))
    return out


def test_docs_exist_and_have_blocks():
    assert (DOCS / "architecture.md").is_file()
    assert (DOCS / "sweeps.md").is_file()
    assert len(_blocks()) > 0


@pytest.mark.parametrize("source", _blocks())
def test_block_parses_and_imports_resolve(source):
    tree = ast.parse(source)          # syntax of the whole block
    imports = [n for n in tree.body
               if isinstance(n, (ast.Import, ast.ImportFrom))]
    mod = ast.Module(body=imports, type_ignores=[])
    exec(compile(mod, "<doc-block>", "exec"), {})  # imports must resolve
